"""Query results: enriched wrapper over the runtime's RuntimeResult.

QueryResult delegates the raw execution fields (`accepted`, `map_values`,
`stage_stats`, ...) and adds the query-level conveniences the examples
and benchmarks kept re-implementing: lazy gold comparison
(`.metrics()` — the gold execution runs at most once per (corpus, query),
memoized by the Session), accepted-item access, speedup reporting, and
`.explain_analyze()` — the planned ExplainReport re-rendered with this
execution's measured per-stage telemetry next to the planner's numbers.

ResultStream is the `.stream()` terminal verb's iterator: it yields
PartitionResult objects as partitions settle, and exposes the
whole-corpus QueryResult as `.result` once the stream finishes (accessing
it early drains the remaining partitions). Because every PartitionResult
carries its per-partition StageStats delta, the stream maintains live
merged telemetry (`.stage_stats`, `.tuples_settled`, `.progress`) over
the partitions consumed so far — truthful progress reporting at zero
extra execution cost.
"""
from __future__ import annotations

from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.executor import evaluate_vs_gold
from repro.core.logical import Query
from repro.runtime.executor import (PartitionResult, RuntimeResult,
                                    StageStats)


class QueryResult:
    """Result of executing a SemFrame (or a plan) over a corpus."""

    def __init__(self, session, query: Query, items: Sequence[Any],
                 raw: RuntimeResult):
        self.session = session
        self.query = query
        self.items = items
        self.raw = raw
        self._metrics_cache: Optional[Dict[str, float]] = None
        # Populated by the QueryScheduler when this result came through
        # concurrent admission: a QueryTelemetry with queue wait, slot
        # occupancy, and cross-query coalescing counters.
        self.sched = None

    # ---------------- raw execution fields ----------------

    @property
    def accepted(self) -> np.ndarray:
        return self.raw.accepted

    @property
    def map_values(self) -> Dict[int, np.ndarray]:
        return self.raw.map_values

    @property
    def runtime_s(self) -> float:
        """Summed measured operator time across all flushes (total work;
        dispatcher-invariant up to timing noise)."""
        return self.raw.runtime_s

    @property
    def wall_s(self) -> float:
        """Elapsed wall clock of the execution — what the caller waited.
        Under a parallel dispatcher wall_s < runtime_s; the ratio is the
        realized overlap speedup."""
        return self.raw.wall_s

    @property
    def stage_stats(self) -> List[StageStats]:
        return self.raw.stage_stats

    def engine_totals(self) -> Dict[str, Dict[str, Any]]:
        """Measured execution totals per engine (wall_s, n_tuples,
        n_llm_calls, kv_bytes, n_batches) — an exact partition of the
        run's totals, since every stage runs on exactly one engine.
        Single-engine sessions report one "" bucket."""
        from repro.runtime.executor import stage_stats_by_engine
        return stage_stats_by_engine(self.raw.stage_stats)

    @property
    def n_llm_tuples(self) -> int:
        return self.raw.n_llm_tuples

    @property
    def n_partitions(self) -> int:
        return self.raw.n_partitions

    @property
    def dispatcher(self) -> str:
        return self.raw.dispatcher

    # ---------------- conveniences ----------------

    def matches(self) -> List[Any]:
        """The accepted corpus items, in corpus order."""
        return [it for it, ok in zip(self.items, self.accepted) if ok]

    def gold(self) -> "QueryResult":
        """The gold reference execution for the same (query, corpus) —
        memoized by the session, so repeated calls are free."""
        raw = self.session.gold(self.query, self.items)
        return QueryResult(self.session, self.query, self.items, raw)

    def metrics(self, vs: Any = None) -> Dict[str, float]:
        """Global precision/recall (+ tp/fp/fn) of this result.

        vs=None compares against the session's gold reference execution
        (computed lazily, once). Pass another QueryResult/RuntimeResult
        to compare against that instead.
        """
        if vs is None:
            if self._metrics_cache is None:
                self._metrics_cache = evaluate_vs_gold(
                    self.raw, self.session.gold(self.query, self.items),
                    self.query.semantic_ops)
            return self._metrics_cache
        ref = vs.raw if isinstance(vs, QueryResult) else vs
        return evaluate_vs_gold(self.raw, ref, self.query.semantic_ops)

    def aggregate(self) -> Dict[Any, Any]:
        """Group-wise aggregates of the query's SemAgg operator: a dict
        keyed by `group_by` column value (a single None key when
        ungrouped) over the accepted survivors. ``how="mode"`` returns
        the most common committed extraction per group (ties break
        toward the smallest value token, deterministically);
        ``how="count"`` the surviving member count per group."""
        from repro.core.logical import SemAgg
        aggs = [(li, op) for li, op in enumerate(self.query.semantic_ops)
                if isinstance(op, SemAgg)]
        if not aggs:
            raise ValueError("aggregate() needs a SemAgg in the query "
                             "(add .sem_agg before the terminal verb)")
        li, op = aggs[-1]
        vals = self.map_values.get(li)
        groups: Dict[Any, List[int]] = {}
        for i, (it, ok) in enumerate(zip(self.items, self.accepted)):
            if not ok:
                continue
            key = None if op.group_by is None else \
                (getattr(it, "row", {}) or {}).get(op.group_by)
            groups.setdefault(key, []).append(i)
        out: Dict[Any, Any] = {}
        for gkey, idxs in groups.items():
            if op.how == "count":
                out[gkey] = len(idxs)
            else:
                counts: Dict[int, int] = {}
                for i in idxs:
                    v = int(vals[i])
                    counts[v] = counts.get(v, 0) + 1
                out[gkey] = max(counts.items(),
                                key=lambda kv: (kv[1], -kv[0]))[0]
        return out

    def explain_analyze(self):
        """EXPLAIN ANALYZE: the planned ExplainReport for this (query,
        corpus) with this execution's measured telemetry filled in —
        per-stage measured cost/batch/KV next to the planned columns,
        plus runtime_s vs wall_s for the whole run. The planned columns
        come from the plan that *produced this result* (carried on the
        RuntimeResult), never a re-derived one — measured-feedback
        recording after the run can change what session.plan() would
        return today, and pairing those stages with this run's stats
        would be exactly the kind of telemetry lie this report exists
        to rule out."""
        from repro.api.explain import ExplainReport
        plan = self.raw.plan
        if plan is None:     # result constructed outside the runtime
            plan = self.session.plan(self.query, self.items)
        report = ExplainReport.from_plan(self.session, self.query,
                                         self.items, plan)
        report = report.with_measured(self.raw)
        if getattr(self.raw, "remote", None):
            report = report.with_remote(self.raw.remote)
        if self.sched is not None:
            report = report.with_scheduler(self.sched)
        return report

    def speedup_vs_gold(self) -> float:
        """Measured speedup over the gold reference execution, on elapsed
        wall clock when both sides measured it (so parallel dispatch
        shows its real speedup), else on summed operator time."""
        gold = self.session.gold(self.query, self.items)
        if self.raw.wall_s > 0 and gold.wall_s > 0:
            return gold.wall_s / max(self.raw.wall_s, 1e-9)
        return gold.runtime_s / max(self.raw.runtime_s, 1e-9)

    def __len__(self) -> int:
        return int(self.accepted.sum())

    def __repr__(self) -> str:
        return (f"QueryResult({int(self.accepted.sum())}/"
                f"{self.accepted.size} accepted, "
                f"runtime={self.runtime_s:.2f}s, "
                f"partitions={self.n_partitions})")


class JoinResult:
    """Result of executing a two-corpus semantic join (a JoinFrame).

    Wraps the runtime TreeResult: one RuntimeResult per role (left /
    right side cascades, pair cascade over the blocked survivor pairs)
    plus the accepted ``(left_id, right_id)`` pairs. `.metrics()`
    compares the pair-id set against the gold join — both sides' gold
    plans and the gold pair scorer — memoized by the Session so it runs
    at most once per (corpora, tree)."""

    def __init__(self, session, left_items: Sequence[Any],
                 right_items: Sequence[Any], raw):
        self.session = session
        self.left_items = left_items
        self.right_items = right_items
        self.raw = raw                       # runtime.tree.TreeResult
        self._metrics_cache: Optional[Dict[str, float]] = None

    # ---------------- raw execution fields ----------------

    @property
    def pair_ids(self) -> List[Any]:
        """Accepted (left_id, right_id) tuples, deterministic order."""
        return self.raw.pair_ids

    @property
    def pair_items(self) -> List[Any]:
        """The blocked survivor pair corpus the pair cascade scored."""
        return self.raw.pair_items

    @property
    def stage_stats(self) -> List[StageStats]:
        """Merged tree telemetry: every role's stages under tree-unique
        logical indices (tiles exactly like single-pipeline stats)."""
        return self.raw.stage_stats

    @property
    def runtime_s(self) -> float:
        return self.raw.runtime_s

    @property
    def wall_s(self) -> float:
        return self.raw.wall_s

    @property
    def n_llm_tuples(self) -> int:
        return self.raw.n_llm_tuples

    def role(self, name: str) -> RuntimeResult:
        """One role's raw RuntimeResult ('left' | 'right' | 'pair')."""
        return self.raw.roles[name]

    # ---------------- conveniences ----------------

    def matches(self) -> List[Any]:
        """The accepted PairItems, in deterministic left-major order."""
        acc = self.raw.roles["pair"].accepted
        return [p for p, ok in zip(self.raw.pair_items, acc) if ok]

    def gold(self):
        """The gold tree execution for the same (corpora, tree) —
        memoized by the session."""
        return self.session.gold_tree(self.raw.plan, self.left_items,
                                      self.right_items)

    def metrics(self) -> Dict[str, float]:
        """Pair-id-set recall / precision / F1 against the gold join
        (computed lazily, gold runs at most once)."""
        if self._metrics_cache is None:
            from repro.runtime.tree import evaluate_pairs
            self._metrics_cache = evaluate_pairs(self.raw, self.gold())
        return self._metrics_cache

    def explain_analyze(self):
        """Tree-shaped EXPLAIN ANALYZE: the planned TreeExplainReport
        with each role's measured execution telemetry filled in."""
        from repro.api.explain import TreeExplainReport
        report = TreeExplainReport.from_plan(
            self.session, self.raw.plan, len(self.left_items),
            len(self.right_items))
        return report.with_measured(self.raw)

    def __len__(self) -> int:
        return len(self.raw.pair_ids)

    def __repr__(self) -> str:
        return (f"JoinResult({len(self.raw.pair_ids)} pairs of "
                f"{len(self.raw.pair_items)} scored, "
                f"runtime={self.runtime_s:.2f}s)")


class ResultStream(Iterator[PartitionResult]):
    """Iterator over per-partition results; `.result` is the final
    whole-corpus QueryResult (draining any unconsumed partitions).

    Live telemetry over the partitions consumed so far — every
    PartitionResult carries the per-stage StageStats delta accounted
    since the previous emission, and the stream folds them together:

      .stage_stats     — merged per-stage stats (plan order of first
                         appearance); equals the final result's stats
                         once the stream is exhausted
      .tuples_settled  — corpus tuples whose decisions are final
      .progress        — settled fraction of the corpus, 0.0 .. 1.0
    """

    def __init__(self, session, query: Query, items: Sequence[Any], gen):
        self.session = session
        self.query = query
        self.items = items
        self._gen = gen
        self._final: Optional[QueryResult] = None
        self._closed = False
        self._live: Dict[Tuple[int, int, str], StageStats] = {}
        self._settled = 0

    def __iter__(self) -> "ResultStream":
        return self

    def __next__(self) -> PartitionResult:
        if self._final is not None or self._closed:
            raise StopIteration
        try:
            part = next(self._gen)
        except StopIteration as stop:
            self._final = QueryResult(self.session, self.query, self.items,
                                      stop.value)
            raise StopIteration from None
        self._settled += len(part)
        for sg in part.stage_stats:
            key = (sg.logical_idx, sg.stage, sg.op_name)
            m = self._live.get(key)
            if m is None:
                self._live[key] = sg.copy()
            else:
                m.merge(sg)
        return part

    @property
    def stage_stats(self) -> List[StageStats]:
        """Merged per-stage stats over the partitions consumed so far."""
        return list(self._live.values())

    @property
    def tuples_settled(self) -> int:
        return self._settled

    @property
    def progress(self) -> float:
        """Fraction of the corpus whose decisions are final."""
        return self._settled / max(len(self.items), 1)

    @property
    def result(self) -> QueryResult:
        """The whole-corpus QueryResult; exhausts the stream if partitions
        remain unconsumed."""
        while self._final is None:
            if self._closed:
                raise RuntimeError("ResultStream was closed before the "
                                   "execution finished")
            try:
                next(self)
            except StopIteration:
                break
        assert self._final is not None
        return self._final

    def close(self) -> None:
        """Abandon the stream without executing remaining partitions."""
        self._closed = True
        self._gen.close()
