"""repro.api — the declarative front door to the Stretto engine.

One import gives the whole query lifecycle::

    from repro.api import Session, SessionConfig

    with Session(SessionConfig(partition_size=256)) as sess:
        frame = (sess.frame(corpus)
                 .sem_filter("mentions topic 1", task_id=1)
                 .sem_map("extract field 2", task_id=2)
                 .with_guarantees(recall=0.75, precision=0.75))
        print(frame.explain())          # plan + cascade table, no execution
        result = frame.execute()        # streaming runtime, full corpus
        print(result.metrics())         # lazy gold comparison
        for part in frame.stream():     # per-partition incremental results
            ...

Layering: `Session` owns the engine lifecycle (cache store, model
registration, profile building, backend + dispatcher resolution);
`SemFrame` is a lazy immutable builder that compiles to the stable
internal layer (`core.logical.Query` -> `core.planner.plan_query` ->
`runtime.executor.run_plan`/`iter_plan`). The internal layer stays public
and supported — the api package adds no planning or execution logic of
its own, so everything the parity tests pin (bit-identical decisions,
equal plan stages) holds by construction.
"""
from repro.api.explain import ExplainReport, ExplainStage, TreeExplainReport
from repro.api.frame import JoinFrame, SemFrame
from repro.api.result import JoinResult, QueryResult, ResultStream
from repro.api.session import EngineSpec, Session, SessionConfig

__all__ = ["EngineSpec", "ExplainReport", "ExplainStage", "JoinFrame",
           "JoinResult", "QueryResult", "ResultStream", "SemFrame",
           "Session", "SessionConfig", "TreeExplainReport"]
