"""Session: engine lifecycle + configuration behind the declarative API.

A Session owns everything `examples/quickstart.py` used to hand-wire:
the CacheStore(s), the ServingEngine(s), planted-model registration,
KV-cache profile building (the paper's offline phase), runtime backend
construction, and the planner/executor configuration — all declared once
in a `SessionConfig`. Queries are built against it with
``session.frame(items)`` (see repro.api.frame).

Engines are declarative and heterogeneous: ``SessionConfig(engines=
(EngineSpec("fast", ...), EngineSpec("accurate", ...)))`` declares a
named pool — each spec owns its model zoo, compression ladder, cache
store and serving limits, the session builds and profiles each engine
lazily per corpus, and the runtime backend becomes a `PoolBackend` whose
candidate union lets the planner place every cascade stage on one
engine. The legacy flat fields (`models`/`sm_ratios`/`lg_ratios`/...)
compile to a single spec named "default" and stay bit-identical to
pre-pool sessions.

The Session compiles to, and never bypasses, the stable internal layer:
plans come from `core.planner.plan_query`, execution goes through
`runtime.executor.run_plan`/`iter_plan`, gold references through
`runtime.plan_utils.gold_plan_for`. It adds lifecycle + memoization only
(profile building per corpus, gold executions per (corpus, query)).
"""
from __future__ import annotations

import shutil
import tempfile
import threading
import weakref
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.logical import Query
from repro.core.optimizer import PlannerConfig
from repro.core.planner import plan_query
from repro.core.physical import PhysicalPlan
from repro.core.profiling import MeasuredBatchStore, batch_drift
from repro.runtime.backend import Backend, as_backend
from repro.runtime.dispatch import DEFAULT_COALESCE
from repro.runtime.executor import RuntimeResult, iter_plan, run_plan
from repro.runtime.plan_utils import gold_plan_for

_UNSET = object()     # "inherit the session default" sentinel


def _affinity_workers(dispatcher) -> Optional[int]:
    """Normalize an EngineSpec.dispatcher affinity declaration to a thread
    count: an int, or a ``threads[:N]`` spec string. None: no affinity."""
    if dispatcher is None:
        return None
    if isinstance(dispatcher, int):
        n = dispatcher
    elif isinstance(dispatcher, str):
        kind, _, arg = dispatcher.partition(":")
        if kind != "threads":
            raise ValueError(
                f"engine dispatcher affinity {dispatcher!r}: only "
                f"'threads[:N]' (or an int worker count) is supported")
        n = int(arg) if arg else 1
    else:
        raise ValueError(f"cannot read engine dispatcher affinity "
                         f"{dispatcher!r} (int or 'threads[:N]')")
    if n <= 0:
        raise ValueError(f"engine dispatcher affinity must be positive, "
                         f"got {n}")
    return n


@dataclass(frozen=True)
class EngineSpec:
    """One named serving engine in a Session's pool.

    Each spec owns a full engine identity: its model zoo, its compression
    ladder (and therefore its candidate operators), its cache store, its
    memory/batch limits — so a pool can mix a small fast tier against a
    large accurate tier and let the planner place every stage.

      name             — unique engine name; pooled operators are keyed
                         ``name/op`` everywhere (plans, StageStats,
                         MeasuredBatchStore, EXPLAIN's engine column)
      models           — planted-zoo model names this engine registers;
                         models[0] is the "sm" tier, models[-1] the "lg"
                         tier (a single entry serves as both)
      sm_ratios / lg_ratios / include_cheap — candidate ladder, exactly
                         as the flat SessionConfig fields
      profile_ratios   — offline ladder to prefill (None: union of the
                         candidate ladders, plus 0.0 for gold)
      cache_dir        — this engine's store root (None: session-owned
                         tempdir, removed on close)
      prefill_batch / memory_budget_bytes / max_batch / model_seed —
                         per-engine serving limits, as before
      dispatcher       — optional thread-affinity hint (int workers or
                         ``threads[:N]``): under a "threads" session
                         dispatcher this engine's flushes get a dedicated
                         pool of that size
      cost_scale       — static cost multiplier applied to this engine's
                         candidates when the pool orders them (declare a
                         remote/expensive tier pricier without faking its
                         measured wall time)
      kernels          — attention kernel backend for this engine's decode
                         flushes: "auto" | "pallas" | "interpret" | "ref"
                         (None: the STRETTO_KERNELS env var, read at flush
                         time, defaulting to "auto")
      fused            — feed the whole operator query through one fused
                         attention dispatch per flush instead of a
                         per-token scan (None: STRETTO_FUSED, default on)
      device_cache     — keep loaded profile batches device-resident in an
                         LRU bounded by memory_budget_bytes; repeat
                         flushes skip reload + H2D copy and do NOT count
                         kv_bytes (None: STRETTO_DEVICE_CACHE, default on)
      async_h2d        — overlap H2D transfers with decode compute: the
                         engine prefetches the next flush batch's KV
                         caches while the current batch decodes and
                         donates consumed cache buffers back to XLA
                         (surfaced as h2d_overlap_s / donated_bytes in
                         EXPLAIN ANALYZE; None: STRETTO_ASYNC_H2D,
                         default on). Never changes results.
      device           — pin this engine on one device: an index into
                         jax.devices() (wrapped modulo the device count,
                         so specs stay valid on smaller hosts). Params
                         are placed there once and every flush computes
                         there. None: jax's default device. A "mesh"
                         session dispatcher overrides this per corpus
                         shard with its own mesh placement.
      sm_int8 / lg_int8 — compression ratios to ALSO store as int8
                         quantized profiles; each becomes a distinct
                         cascade candidate (operator suffix ``i8``) priced
                         at the halved HBM traffic
      address          — serve this engine REMOTELY: "host:port" of a
                         running `repro.launch.remote_worker` (which owns
                         the actual model zoo / ladder / store — launch it
                         with the same values for bit-parity with a local
                         spec). The session builds no local engine for the
                         slot; the pool member becomes a
                         RemoteEngineMember whose flushes go over the
                         wire. Mutually exclusive with `device` and
                         `dispatcher` affinity — a remote engine's
                         placement belongs to its worker process. The
                         gold engine must stay local (fallback +
                         reference execution need an in-process engine).
      on_unavailable   — remote degradation policy: "fallback" (default)
                         re-routes failed flushes to the gold/local
                         engine mid-run and records it in telemetry;
                         "fail" raises RemoteEngineError
      timeout_s        — per-call wire timeout for a remote engine
      remote_retries   — transport retries per idempotent remote call
    """
    name: str
    models: Tuple[str, ...] = ("sm", "lg")
    sm_ratios: Tuple[float, ...] = (0.8, 0.5, 0.0)
    lg_ratios: Tuple[float, ...] = (0.8, 0.5, 0.3)
    include_cheap: bool = True
    profile_ratios: Optional[Tuple[float, ...]] = None
    cache_dir: Optional[str] = None
    prefill_batch: int = 16
    memory_budget_bytes: float = 2e9
    max_batch: int = 128
    model_seed: int = 1
    dispatcher: Optional[Any] = None
    cost_scale: float = 1.0
    kernels: Optional[str] = None
    fused: Optional[bool] = None
    device_cache: Optional[bool] = None
    async_h2d: Optional[bool] = None
    device: Optional[int] = None
    sm_int8: Tuple[float, ...] = ()
    lg_int8: Tuple[float, ...] = ()
    address: Optional[str] = None
    on_unavailable: str = "fallback"
    timeout_s: float = 30.0
    remote_retries: int = 2

    def __post_init__(self):
        if not self.name or not isinstance(self.name, str):
            raise ValueError("EngineSpec.name must be a non-empty string")
        if self.address is not None:
            if ":" not in self.address:
                raise ValueError(
                    f"engine {self.name!r}: address must be 'host:port', "
                    f"got {self.address!r}")
            # a remote engine's placement/affinity belongs to its worker
            # process — declaring both is a contradiction, rejected here
            # like duplicate names / unknown gold engines
            if self.device is not None:
                raise ValueError(
                    f"engine {self.name!r}: address= and device= are "
                    f"mutually exclusive — a remote engine is placed by "
                    f"its worker process, not this session")
            if self.dispatcher is not None:
                raise ValueError(
                    f"engine {self.name!r}: address= and dispatcher= "
                    f"affinity are mutually exclusive — a remote "
                    f"engine's flushes run on its worker, not a local "
                    f"thread pool")
        if self.on_unavailable not in ("fallback", "fail"):
            raise ValueError(
                f"engine {self.name!r}: on_unavailable must be "
                f"'fallback' or 'fail', got {self.on_unavailable!r}")
        if self.timeout_s <= 0:
            raise ValueError(f"engine {self.name!r}: timeout_s must be "
                             f"positive, got {self.timeout_s}")
        if self.remote_retries < 0:
            raise ValueError(f"engine {self.name!r}: remote_retries must "
                             f"be >= 0, got {self.remote_retries}")
        if self.device is not None and (not isinstance(self.device, int)
                                        or self.device < 0):
            raise ValueError(
                f"engine {self.name!r}: device must be a non-negative "
                f"index into jax.devices(), got {self.device!r}")
        if self.kernels is not None:
            from repro.kernels.ops import VALID_BACKENDS
            if self.kernels not in VALID_BACKENDS:
                raise ValueError(
                    f"engine {self.name!r}: kernels={self.kernels!r} is "
                    f"not one of {VALID_BACKENDS}")
        if "/" in self.name:
            raise ValueError(
                f"EngineSpec.name {self.name!r} must not contain '/' — it "
                f"is the engine/op separator in pooled operator names")
        if not self.models:
            raise ValueError(f"engine {self.name!r} declares no models")
        if self.cost_scale <= 0:
            raise ValueError(f"engine {self.name!r}: cost_scale must be "
                             f"positive, got {self.cost_scale}")
        _affinity_workers(self.dispatcher)      # validate eagerly

    @property
    def sm_model(self) -> str:
        return self.models[0]

    @property
    def lg_model(self) -> str:
        return self.models[-1]

    def ladder(self) -> Tuple[float, ...]:
        """Compression ratios this engine's profiles are built at (gold
        0.0 always included — its gold operator needs it)."""
        if self.profile_ratios is not None:
            return tuple(sorted({0.0, *self.profile_ratios}))
        return tuple(sorted({0.0, *self.sm_ratios, *self.lg_ratios}))


@dataclass(frozen=True)
class SessionConfig:
    """Everything a Session needs, declared once.

    Engines — two equivalent declarations:
      engines          — a tuple of named EngineSpec entries: the session
                         serves a heterogeneous pool, the runtime backend
                         is a PoolBackend unioning every engine's
                         candidate ladder, and the planner places each
                         stage on one engine. Names must be unique;
                         engines=() is an error (declare at least one).
      <flat fields>    — the legacy single-engine form below; it compiles
                         to one default EngineSpec (see resolved_engines)
                         and behaves bit-identically to declaring nothing
                         but that spec.
      gold_engine      — which engine's gold operator defines the quality
                         reference (default: the first declared engine).

    Engine / offline phase (legacy flat form)
      cache_dir        — on-disk cache store root (None: fresh tempdir,
                         removed when the session closes)
      models           — planted-zoo model names to register
      profile_ratios   — compression ladder to prefill (None: union of the
                         backend ladders below, plus 0.0 for gold)
      prefill_batch    — items per prefill call during profile building
      memory_budget_bytes / max_batch — serving engine limits

    Backend (cascade candidate ladder)
      sm_ratios / lg_ratios / include_cheap — KVCacheBackend ladder

    Planner
      planner          — PlannerConfig (None: library defaults, per call)
      sample_frac      — profiling sample fraction
      seed             — profiling sample seed
      reorder          — enable the DP/greedy stage reorderer

    Execution
      partition_size   — tuples ingested per streaming step (None: whole
                         corpus at once)
      coalesce         — min pending tuples before a stage flush (None:
                         DEFAULT_COALESCE; also what the planner's
                         batch-aware cost model amortizes over)
      dispatcher       — runtime dispatcher spec ("inline" |
                         "threads[:N]" | "sharded[:N]" | "mesh[:N]"),
                         a Dispatcher instance, or None to read
                         STRETTO_DISPATCHER. "mesh:N" scatters the
                         partition loop over N corpus shards pinned onto
                         the devices of a jax data-parallel mesh —
                         decisions stay bit-identical to "inline"

    Measured feedback (the measure -> plan loop)
      feedback         — seeds the session's MeasuredBatchStore: a store
                         instance, a directory of stage_stats*.json
                         trajectory snapshots to aggregate, or None for a
                         fresh empty store. Once the store holds measured
                         telemetry (loaded, via Session.record_measured,
                         or by a replan-on-drift), Session.plan() prices
                         operators at measured flush widths instead of
                         the static coalesce default.
    """
    cache_dir: Optional[str] = None
    models: Tuple[str, ...] = ("sm", "lg")
    profile_ratios: Optional[Tuple[float, ...]] = None
    prefill_batch: int = 16
    memory_budget_bytes: float = 2e9
    max_batch: int = 128
    model_seed: int = 1

    sm_ratios: Tuple[float, ...] = (0.8, 0.5, 0.0)
    lg_ratios: Tuple[float, ...] = (0.8, 0.5, 0.3)
    include_cheap: bool = True

    # kernel fast path + transfer overlap (see EngineSpec for semantics)
    kernels: Optional[str] = None
    fused: Optional[bool] = None
    device_cache: Optional[bool] = None
    async_h2d: Optional[bool] = None
    sm_int8: Tuple[float, ...] = ()
    lg_int8: Tuple[float, ...] = ()

    engines: Optional[Tuple[EngineSpec, ...]] = None
    gold_engine: Optional[str] = None

    # tenants sharing the session under a QueryScheduler: TenantSpec
    # entries (repro.scheduler) declaring tier / fair-share weight /
    # keep-warm cache policy. None: scheduled sessions run every query
    # under an implicit "default" standard tenant. Ignored outside
    # Session.scheduler().
    tenants: Optional[Tuple[Any, ...]] = None

    planner: Optional[PlannerConfig] = None
    sample_frac: float = 0.15
    seed: int = 0
    reorder: bool = True

    partition_size: Optional[int] = None
    coalesce: Optional[int] = None
    dispatcher: Optional[Any] = None

    feedback: Optional[Any] = None

    def __post_init__(self):
        if self.engines is not None:
            object.__setattr__(self, "engines", tuple(self.engines))
            if not self.engines:
                raise ValueError(
                    "SessionConfig(engines=()) declares no engines — "
                    "declare at least one EngineSpec, or omit `engines` "
                    "for the legacy single-engine form")
            names = [e.name for e in self.engines]
            dups = sorted({n for n in names if names.count(n) > 1})
            if dups:
                raise ValueError(f"duplicate engine name(s): {dups}")
        if self.gold_engine is not None:
            names = [e.name for e in self.resolved_engines()]
            if self.gold_engine not in names:
                raise ValueError(
                    f"gold_engine {self.gold_engine!r} is not a declared "
                    f"engine (engines: {names})")
        specs = self.resolved_engines()
        gold = self.gold_engine if self.gold_engine is not None \
            else specs[0].name
        gold_spec = next(s for s in specs if s.name == gold)
        if gold_spec.address is not None:
            raise ValueError(
                f"gold engine {gold!r} is remote (address="
                f"{gold_spec.address!r}) — the gold engine must be local: "
                f"it anchors the quality reference and serves as the "
                f"on_unavailable='fallback' target, both of which need an "
                f"in-process engine. Declare a local gold engine (or set "
                f"gold_engine to a local spec).")
        if self.tenants is not None:
            from repro.scheduler.tenants import validate_tenants
            object.__setattr__(self, "tenants",
                               validate_tenants(self.tenants))

    def resolved_engines(self) -> Tuple[EngineSpec, ...]:
        """The engine pool this config declares. The legacy flat fields
        (models / sm_ratios / lg_ratios / cache_dir / ...) compile to a
        single spec named "default" — the back-compat shim that keeps
        every pre-pool config planning and deciding bit-identically."""
        if self.engines is not None:
            return self.engines
        return (EngineSpec(
            name="default", models=self.models,
            sm_ratios=self.sm_ratios, lg_ratios=self.lg_ratios,
            include_cheap=self.include_cheap,
            profile_ratios=self.profile_ratios, cache_dir=self.cache_dir,
            prefill_batch=self.prefill_batch,
            memory_budget_bytes=self.memory_budget_bytes,
            max_batch=self.max_batch, model_seed=self.model_seed,
            kernels=self.kernels, fused=self.fused,
            device_cache=self.device_cache, async_h2d=self.async_h2d,
            sm_int8=tuple(self.sm_int8), lg_int8=tuple(self.lg_int8)),)

    def ladder(self) -> Tuple[float, ...]:
        """The compression ratios profiles are built at (gold 0.0 always
        included — the reference backend needs it). Single-engine view
        only: a pool has one ladder per engine, so ask each resolved
        EngineSpec instead."""
        specs = self.resolved_engines()
        if len(specs) > 1:
            raise ValueError(
                "a multi-engine SessionConfig has per-engine ladders; "
                "call .ladder() on each spec in resolved_engines()")
        return specs[0].ladder()


class Session:
    """Context-managed front door to the engine.

    Three construction modes:

      Session()                      — owns everything: fresh cache store,
                                       planted models, profiles built
                                       lazily per corpus on first use
      Session(engine=eng)            — adopts an existing ServingEngine
                                       (models/profiles are the caller's;
                                       call .prepare(items) if needed)
      Session(backend=b)             — wraps any runtime Backend (e.g. an
                                       OracleBackend over a registry);
                                       no engine, no profile building —
                                       gold references come from the
                                       backend's own gold operators
    """

    def __init__(self, config: Optional[SessionConfig] = None, *,
                 engine=None, backend=None, reference=None, **overrides):
        if config is None:
            config = SessionConfig(**overrides)
        elif overrides:
            config = replace(config, **overrides)
        self.config = config
        self._closed = False
        # serializes the session's mutable memo state (plan/gold caches,
        # profile preparation, corpus tokens, measured feedback) so the
        # scheduler's concurrent query drivers can share one session.
        # Reentrant: plan() takes it and calls prepare(), which takes it
        # again. Execution itself (run_plan flushes) never holds it.
        self._state_lock = threading.RLock()
        self._owned_cache_dirs: List[str] = []
        self._prepared: set = set()
        self._gold_cache: Dict[Any, RuntimeResult] = {}
        self._plan_cache: Dict[Any, PhysicalPlan] = {}
        # stable per-object corpus tokens for items without an item_id:
        # CPython reuses id() after GC, so raw ids must never key a memo
        # (two distinct corpora could silently share plan/gold entries).
        # Weak-referenceable objects get a counter token that dies with
        # them; the rest are pinned for the session's lifetime so their
        # ids cannot be recycled (growth bounded by the distinct keyless
        # corpora the session sees — over-invalidation is safe, collision
        # is not).
        self._obj_tokens: "weakref.WeakKeyDictionary[Any, int]" = \
            weakref.WeakKeyDictionary()
        self._pinned_tokens: Dict[int, int] = {}
        self._id_pins: List[Any] = []
        self._next_token = 0
        # measured execution feedback driving the measure -> plan loop
        fb = config.feedback
        if isinstance(fb, MeasuredBatchStore):
            self.measured = fb
        elif isinstance(fb, str):
            self.measured = MeasuredBatchStore.from_dir(fb)
        else:
            self.measured = MeasuredBatchStore()
        self.n_replans = 0

        # the declared engine pool: every session resolves to named specs
        # (legacy flat configs become one spec named "default")
        self.engine_specs: Tuple[EngineSpec, ...] = config.resolved_engines()
        self._specs_by_name = {s.name: s for s in self.engine_specs}
        self.gold_engine_name: str = config.gold_engine \
            if config.gold_engine is not None else self.engine_specs[0].name
        # remote engine members (EngineSpec(address=...)), built alongside
        # the pool backend; profile sync rides on prepare()
        self._remote_members: Dict[str, Any] = {}
        self._engine_workers: Dict[str, int] = {}
        for spec in self.engine_specs:
            w = _affinity_workers(spec.dispatcher)
            if w is not None:
                self._engine_workers[spec.name] = w
        self._affinity_disp = None

        self._owns_engine = engine is None and backend is None
        if backend is not None and engine is None:
            self.engines: Dict[str, Any] = {}
            self.engine = None
        elif engine is not None:
            if len(self.engine_specs) > 1:
                raise ValueError(
                    "Session(engine=...) adopts exactly one engine; a "
                    "multi-engine SessionConfig must let the session "
                    "build its own pool (or wrap a prebuilt PoolBackend "
                    "via Session(backend=...))")
            # adopted engine: it serves the first declared spec's slot
            self.engines = {self.engine_specs[0].name: engine}
            self.engine = engine
        else:
            self.engines = self._build_engines()
            # the session's "primary" engine: the first *local* spec's
            # (remote specs build no in-process engine; the gold engine
            # is guaranteed local, so this always resolves)
            first_local = next(s.name for s in self.engine_specs
                               if s.name in self.engines)
            self.engine = self.engines[first_local]
        self.backend: Backend = as_backend(backend) \
            if backend is not None else self._default_backend()
        if reference is not None:
            self.reference = as_backend(reference)
        elif self.engines:
            from repro.runtime.backend import ReferenceBackend
            gold_spec = self._specs_by_name[self.gold_engine_name]
            gold_engine = self.engines.get(gold_spec.name, self.engine)
            self.reference = ReferenceBackend(gold_engine,
                                              lg=gold_spec.lg_model)
        else:
            # no engine: the backend's own gold operators (candidates
            # list, gold last) are the reference
            self.reference = self.backend

    # ---------------- lifecycle ----------------

    def _build_engines(self) -> Dict[str, Any]:
        from repro.cache.store import CacheStore
        from repro.data.synthetic import make_planted_params, planted_config
        from repro.serving.engine import ServingEngine
        engines: Dict[str, Any] = {}
        for spec in self.engine_specs:
            if spec.address is not None:
                continue            # served by a remote worker process
            cache_dir = spec.cache_dir
            if cache_dir is None:
                cache_dir = tempfile.mkdtemp(
                    prefix=f"stretto_session_{spec.name}_")
                self._owned_cache_dirs.append(cache_dir)
            eng = ServingEngine(
                CacheStore(cache_dir),
                memory_budget_bytes=spec.memory_budget_bytes,
                max_batch=spec.max_batch, kernels=spec.kernels,
                fused=spec.fused, device_cache=spec.device_cache,
                async_h2d=spec.async_h2d)
            if spec.device is not None:
                import jax
                devs = jax.devices()
                eng.default_device = devs[spec.device % len(devs)]
            for name in spec.models:
                mcfg = planted_config(name)
                eng.register_model(
                    name, mcfg,
                    make_planted_params(mcfg, seed=spec.model_seed))
            engines[spec.name] = eng
        return engines

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        """Release session-owned resources (idempotent). Only cache
        directories the session created itself are removed."""
        if self._closed:
            return
        self._closed = True
        if self._affinity_disp is not None:
            self._affinity_disp.close()
            self._affinity_disp = None
        for member in self._remote_members.values():
            member.close()
        for d in self._owned_cache_dirs:
            shutil.rmtree(d, ignore_errors=True)
        self._owned_cache_dirs = []

    # ---------------- offline phase ----------------

    def _object_token(self, it: Any) -> int:
        """A session-stable token for an item without an item_id. Unlike
        raw id(), tokens are never recycled: weak-referenceable items get
        a fresh counter entry that disappears with the object (a new
        object can never inherit it), everything else is pinned so its id
        stays unique for the session's lifetime."""
        try:
            tok = self._obj_tokens.get(it)
            if tok is None:
                tok = self._next_token
                self._next_token += 1
                self._obj_tokens[it] = tok
            return tok
        except TypeError:       # unhashable / no weakref support: pin it
            key = id(it)
            tok = self._pinned_tokens.get(key)
            if tok is None:
                self._id_pins.append(it)
                tok = self._next_token
                self._next_token += 1
                self._pinned_tokens[key] = tok
            return tok

    def _corpus_key(self, items: Sequence[Any]) -> Tuple:
        """Cheap corpus fingerprint for profile/plan/gold memoization:
        length plus (item_id, lead token) at a spread of sample
        positions. Items without an `item_id` use a session-held stable
        token (see _object_token) — never a raw id(), which CPython
        recycles after GC; distinct same-length corpora must never share
        a key (over-invalidation is safe, collision is not)."""
        n = len(items)
        step = max(n // 16, 1)
        probe = []
        for it in items[::step]:
            toks = getattr(it, "tokens", None)
            lead = toks[0] if toks is not None and len(toks) else None
            item_id = getattr(it, "item_id", None)
            probe.append((item_id if item_id is not None
                          else ("obj", self._object_token(it)), lead))
        return (n, tuple(probe))

    def corpus_key(self, items: Sequence[Any]) -> Tuple:
        """The session's stable corpus fingerprint, thread-safe (the
        scheduler keys per-tenant warm state on it)."""
        with self._state_lock:
            return self._corpus_key(items)

    def prepare(self, items: Sequence[Any],
                ratios: Optional[Sequence[float]] = None) -> None:
        """Build KV-cache profiles for this corpus (offline phase), per
        engine at each engine's own ladder (`ratios` overrides every
        ladder). Safe to call repeatedly — and from concurrent scheduler
        drivers — each (engine, corpus, ladder) is built once."""
        if not self.engines and not self._remote_members:
            return                      # backend-only session: nothing to do
        with self._state_lock:
            self._prepare_locked(items, ratios)

    def _prepare_locked(self, items: Sequence[Any],
                        ratios: Optional[Sequence[float]]) -> None:
        for spec in self.engine_specs:
            eng = self.engines.get(spec.name)
            if eng is None:
                continue
            ladder = tuple(sorted({0.0, *(ratios or spec.ladder())}))
            key = (spec.name, self._corpus_key(items), ladder,
                   tuple(spec.sm_int8), tuple(spec.lg_int8))
            if key in self._prepared:
                continue
            for name in spec.models:
                quant: set = set()
                if name == spec.sm_model:
                    quant |= set(spec.sm_int8)
                if name == spec.lg_model:
                    quant |= set(spec.lg_int8)
                eng.build_profiles(name, items, ratios=list(ladder),
                                   prefill_batch=spec.prefill_batch,
                                   quant_ratios=sorted(quant))
            self._prepared.add(key)
        # remote members: corpus sync (the worker builds its own ladder
        # lazily on first sync; a hash-matched re-sync is one round trip)
        for name, member in self._remote_members.items():
            key = ("remote", name, self._corpus_key(items))
            if key in self._prepared:
                continue
            member.sync(items)
            self._prepared.add(key)

    def _ensure_prepared(self, items: Sequence[Any]) -> None:
        # adopted engines manage their own profiles; session-owned
        # engines build lazily on first use of a corpus
        if self._owns_engine:
            self.prepare(items)

    # ---------------- backends ----------------

    def backend_for(self, *, engine: Optional[str] = None,
                    sm_ratios: Optional[Tuple[float, ...]] = None,
                    lg_ratios: Optional[Tuple[float, ...]] = None,
                    include_cheap: Optional[bool] = None) -> Backend:
        """A KVCacheBackend over one session engine (default: the first
        declared) with an alternative candidate ladder (defaults: that
        engine's declared ladder). Single-engine view — the session
        default for pool configs is `_default_backend()`."""
        if not self.engines:
            raise RuntimeError("session has no engine: it wraps an "
                               "externally supplied backend")
        name = engine if engine is not None else self.engine_specs[0].name
        spec = self._specs_by_name.get(name)
        if spec is not None and spec.address is not None:
            raise ValueError(
                f"engine {name!r} is remote (address={spec.address!r}) — "
                f"it has no local KVCacheBackend; its candidate ladder "
                f"lives on the worker and is reached through the pool")
        if spec is None or name not in self.engines:
            raise ValueError(f"unknown engine {name!r}; session engines: "
                             f"{sorted(self.engines)}")
        from repro.runtime.backend import KVCacheBackend
        return KVCacheBackend(
            self.engines[name], sm=spec.sm_model, lg=spec.lg_model,
            sm_ratios=sm_ratios if sm_ratios is not None else spec.sm_ratios,
            lg_ratios=lg_ratios if lg_ratios is not None else spec.lg_ratios,
            sm_int8=spec.sm_int8, lg_int8=spec.lg_int8,
            include_cheap=spec.include_cheap if include_cheap is None
            else include_cheap)

    def _default_backend(self) -> Backend:
        """The session's runtime backend: the bare KVCacheBackend for a
        single-engine config (bit-identical to pre-pool sessions —
        operator names stay unprefixed), a PoolBackend routing across
        every declared engine otherwise."""
        if len(self.engine_specs) == 1:
            return self.backend_for()
        from repro.runtime.backend import PoolBackend
        members = []
        for spec in self.engine_specs:
            if spec.address is not None:
                from repro.remote.client import RemoteEngineMember
                member = RemoteEngineMember(
                    spec.name, spec.address, timeout_s=spec.timeout_s,
                    retries=spec.remote_retries,
                    on_unavailable=spec.on_unavailable)
                self._remote_members[spec.name] = member
            else:
                member = self.backend_for(engine=spec.name)
            members.append((spec.name, member))
        pool = PoolBackend(
            members, gold=self.gold_engine_name,
            cost_scales={s.name: s.cost_scale for s in self.engine_specs})
        # a remote member's on_unavailable='fallback' re-routes failed
        # flushes to the gold/local member — always safe: gold scores
        # never degrade decisions (gold is the quality reference)
        for member in self._remote_members.values():
            member.set_fallback(pool.members[self.gold_engine_name])
        return pool

    # ---------------- query building ----------------

    def frame(self, items: Sequence[Any], query: Optional[Query] = None):
        """A lazy SemFrame over `items` (a sequence of corpus items, or
        anything exposing `.items` such as a Dataset). Pass `query` to
        seed the frame from an existing logical Query."""
        from repro.api.frame import SemFrame
        items = getattr(items, "items", items)
        if query is not None:
            return SemFrame(self, items, tuple(query.nodes),
                            query.target_recall, query.target_precision)
        return SemFrame(self, items)

    # ---------------- internal layer (plan / execute / gold) ----------

    def _default_dispatcher(self):
        """The session-default dispatcher argument, honoring per-engine
        thread affinity: when any EngineSpec declares a `dispatcher`
        worker hint and the session default resolves to a "threads" spec,
        a session-owned ThreadPoolDispatcher with dedicated per-engine
        pools is used (completions still apply in global submission
        order, so decisions are unchanged)."""
        spec = self.config.dispatcher
        if not self._engine_workers:
            return spec
        if spec is not None and not isinstance(spec, str):
            return spec                 # caller-supplied instance wins
        from repro.runtime.dispatch import (ThreadPoolDispatcher,
                                            effective_spec)
        eff = effective_spec(spec)
        if not eff.startswith("threads"):
            return spec
        if self._affinity_disp is None:
            _, _, arg = eff.partition(":")
            kwargs: Dict[str, Any] = {
                "engine_workers": dict(self._engine_workers)}
            if arg:
                n = int(arg)
                if n <= 0:
                    # same contract as resolve_dispatcher: a bad count
                    # must fail loudly, not silently clamp to 1 worker
                    raise ValueError(f"dispatcher spec {eff!r}: "
                                     f"worker/shard count must be "
                                     f"positive, got {n}")
                kwargs["n_workers"] = n
            self._affinity_disp = ThreadPoolDispatcher(**kwargs)
        return self._affinity_disp

    def _exec_kwargs(self, partition_size=_UNSET, coalesce=_UNSET,
                     dispatcher=_UNSET) -> Dict[str, Any]:
        cfg = self.config
        return {
            "partition_size": cfg.partition_size
            if partition_size is _UNSET else partition_size,
            "coalesce": cfg.coalesce if coalesce is _UNSET else coalesce,
            "dispatcher": self._default_dispatcher()
            if dispatcher is _UNSET else dispatcher,
        }

    def plan(self, query: Query, items: Sequence[Any]) -> PhysicalPlan:
        """Plan `query` over `items` with the session's planner settings
        (memoized per (corpus, query, measured-feedback version) —
        explain + execute share a plan; recording new measured telemetry
        bumps the store version, so the next plan() re-plans against the
        updated flush widths). When the session's MeasuredBatchStore
        holds telemetry, BatchHint is seeded from measured flush widths
        instead of the static coalesce default."""
        with self._state_lock:
            self._ensure_prepared(items)
            key = (self._corpus_key(items), tuple(query.nodes),
                   query.target_recall, query.target_precision,
                   self.measured.version if len(self.measured) else 0)
            plan = self._plan_cache.get(key)
            if plan is None:
                cfg = self.config
                plan = plan_query(
                    query, items, self.backend, cfg.planner,
                    sample_frac=cfg.sample_frac, seed=cfg.seed,
                    reorder=cfg.reorder,
                    coalesce=cfg.coalesce if cfg.coalesce is not None
                    else DEFAULT_COALESCE,
                    measured=self.measured if len(self.measured) else None)
                self._plan_cache[key] = plan
            return plan

    def record_measured(self, result: RuntimeResult) -> None:
        """Feed a result's measured StageStats into the session's
        MeasuredBatchStore, so subsequent plan() calls price operators at
        the flush widths execution actually delivered."""
        with self._state_lock:
            self.measured.record_result(result)

    def run(self, plan: PhysicalPlan, query: Query, items: Sequence[Any],
            backend: Optional[Backend] = None, *, partition_size=_UNSET,
            coalesce=_UNSET, dispatcher=_UNSET,
            replan_on_drift: Optional[float] = None) -> RuntimeResult:
        """Execute a prebuilt plan through the streaming runtime with the
        session's execution defaults.

        replan_on_drift — when set (a factor > 1), compare each executed
        stage's measured mean flush batch against the plan's expected
        batch after the run; if any stage diverges by more than the
        factor (either direction), record the measured telemetry into the
        session's MeasuredBatchStore, re-plan the query against the
        measured widths, and re-execute once with the corrected plan
        (returning the second result). The paper's cost model is only as
        good as its batch expectations — this is the cheap online
        correction for when reality disagrees. Only valid when the run
        executes the session's own backend: re-planning profiles against
        `self.backend`, so a caller-supplied backend would be re-planned
        on the wrong operator ladder and its stats would pollute the
        session's measured store.
        """
        self._ensure_prepared(items)
        if replan_on_drift is not None and backend is not None \
                and backend is not self.backend:
            raise ValueError(
                "replan_on_drift requires the session backend: re-planning "
                "profiles against session.backend, which is not the "
                "backend this run would execute on")
        kwargs = self._exec_kwargs(partition_size, coalesce, dispatcher)
        before = {n: m.snapshot()
                  for n, m in self._remote_members.items()} or None
        result = run_plan(plan, query, items, backend or self.backend,
                          **kwargs)
        if replan_on_drift is not None:
            drift = batch_drift(plan, result.stage_stats)
            if drift > float(replan_on_drift):
                self.record_measured(result)
                self.n_replans += 1
                new_plan = self.plan(query, items)
                result = run_plan(new_plan, query, items,
                                  backend or self.backend, **kwargs)
        if before is not None:
            from repro.remote.client import remote_run_info
            after = {n: m.snapshot()
                     for n, m in self._remote_members.items()}
            result.remote = remote_run_info(before, after)
        return result

    def iter_run(self, plan: PhysicalPlan, query: Query,
                 items: Sequence[Any], backend: Optional[Backend] = None, *,
                 partition_size=_UNSET, coalesce=_UNSET, dispatcher=_UNSET):
        """Generator form of `run` (yields PartitionResult per settled
        partition; StopIteration.value is the RuntimeResult)."""
        self._ensure_prepared(items)
        return iter_plan(plan, query, items, backend or self.backend,
                         **self._exec_kwargs(partition_size, coalesce,
                                             dispatcher))

    def gold(self, query: Query, items: Sequence[Any]) -> RuntimeResult:
        """The gold reference execution for `query` over `items` (every
        semantic op resolved by the reference backend's gold operator),
        memoized per (corpus, query nodes)."""
        with self._state_lock:
            self._ensure_prepared(items)
            key = (self._corpus_key(items), tuple(query.nodes))
            got = self._gold_cache.get(key)
            if got is None:
                gold_plan = gold_plan_for(query, self.reference)
                got = run_plan(gold_plan, query, items, self.reference,
                               **self._exec_kwargs())
                self._gold_cache[key] = got
            return got

    # ---------------- join trees ----------------

    def plan_tree(self, tree, left_items: Sequence[Any],
                  right_items: Sequence[Any], *,
                  target_recall: float = 0.9,
                  target_precision: float = 0.9):
        """Plan a logical join tree over two corpora with the session's
        planner settings, memoized like `plan` but keyed on *both*
        corpus fingerprints. Profiles are built for each side corpus
        only — the pair cascade's operators decompose to side-item
        engine calls, so the sides' KV-cache profiles serve the pair
        stages too."""
        from repro.core.planner import plan_tree as _plan_tree
        with self._state_lock:
            self._ensure_prepared(left_items)
            self._ensure_prepared(right_items)
            key = ("tree", self._corpus_key(left_items),
                   self._corpus_key(right_items), tree,
                   target_recall, target_precision,
                   self.measured.version if len(self.measured) else 0)
            plan = self._plan_cache.get(key)
            if plan is None:
                cfg = self.config
                plan = _plan_tree(
                    tree, left_items, right_items, self.backend,
                    cfg.planner, target_recall=target_recall,
                    target_precision=target_precision,
                    sample_frac=cfg.sample_frac, seed=cfg.seed,
                    reorder=cfg.reorder,
                    coalesce=cfg.coalesce if cfg.coalesce is not None
                    else DEFAULT_COALESCE,
                    measured=self.measured if len(self.measured) else None)
                self._plan_cache[key] = plan
            return plan

    def run_tree(self, plan, left_items: Sequence[Any],
                 right_items: Sequence[Any],
                 backend: Optional[Backend] = None, *,
                 partition_size=_UNSET, coalesce=_UNSET,
                 dispatcher=_UNSET):
        """Execute a planned join tree — left side, right side, then the
        pair cascade over the blocked survivor pairs — with the
        session's execution defaults. Returns a runtime TreeResult."""
        from repro.runtime.tree import run_tree as _run_tree
        self._ensure_prepared(left_items)
        self._ensure_prepared(right_items)
        return _run_tree(plan, left_items, right_items,
                         backend or self.backend,
                         **self._exec_kwargs(partition_size, coalesce,
                                             dispatcher))

    def gold_tree(self, plan, left_items: Sequence[Any],
                  right_items: Sequence[Any]):
        """The gold reference execution of a join tree (every role run
        under its gold-only plan, gold survivors paired), memoized per
        (both corpora, tree queries)."""
        from repro.runtime.tree import run_gold_tree
        with self._state_lock:
            self._ensure_prepared(left_items)
            self._ensure_prepared(right_items)
            key = ("gold-tree", self._corpus_key(left_items),
                   self._corpus_key(right_items), plan.join,
                   tuple(tuple(plan.queries[r].nodes)
                         for r in ("left", "right", "pair")))
            got = self._gold_cache.get(key)
            if got is None:
                got = run_gold_tree(plan, left_items, right_items,
                                    self.reference, **self._exec_kwargs())
                self._gold_cache[key] = got
            return got

    def scheduler(self, **kwargs):
        """Build a QueryScheduler admitting concurrent queries onto this
        session (see repro.scheduler). Tenants default to the session
        config's `tenants` tuple; keyword arguments are forwarded to the
        QueryScheduler constructor."""
        from repro.scheduler import QueryScheduler
        return QueryScheduler(self, **kwargs)
