"""Session: engine lifecycle + configuration behind the declarative API.

A Session owns everything `examples/quickstart.py` used to hand-wire:
the CacheStore, the ServingEngine, planted-model registration, KV-cache
profile building (the paper's offline phase), runtime backend
construction, and the planner/executor configuration — all declared once
in a `SessionConfig`. Queries are built against it with
``session.frame(items)`` (see repro.api.frame).

The Session compiles to, and never bypasses, the stable internal layer:
plans come from `core.planner.plan_query`, execution goes through
`runtime.executor.run_plan`/`iter_plan`, gold references through
`runtime.plan_utils.gold_plan_for`. It adds lifecycle + memoization only
(profile building per corpus, gold executions per (corpus, query)).
"""
from __future__ import annotations

import shutil
import tempfile
import weakref
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.logical import Query
from repro.core.optimizer import PlannerConfig
from repro.core.planner import plan_query
from repro.core.physical import PhysicalPlan
from repro.core.profiling import MeasuredBatchStore, batch_drift
from repro.runtime.backend import Backend, as_backend
from repro.runtime.dispatch import DEFAULT_COALESCE
from repro.runtime.executor import RuntimeResult, iter_plan, run_plan
from repro.runtime.plan_utils import gold_plan_for

_UNSET = object()     # "inherit the session default" sentinel


@dataclass(frozen=True)
class SessionConfig:
    """Everything a Session needs, declared once.

    Engine / offline phase
      cache_dir        — on-disk cache store root (None: fresh tempdir,
                         removed when the session closes)
      models           — planted-zoo model names to register
      profile_ratios   — compression ladder to prefill (None: union of the
                         backend ladders below, plus 0.0 for gold)
      prefill_batch    — items per prefill call during profile building
      memory_budget_bytes / max_batch — serving engine limits

    Backend (cascade candidate ladder)
      sm_ratios / lg_ratios / include_cheap — KVCacheBackend ladder

    Planner
      planner          — PlannerConfig (None: library defaults, per call)
      sample_frac      — profiling sample fraction
      seed             — profiling sample seed
      reorder          — enable the DP/greedy stage reorderer

    Execution
      partition_size   — tuples ingested per streaming step (None: whole
                         corpus at once)
      coalesce         — min pending tuples before a stage flush (None:
                         DEFAULT_COALESCE; also what the planner's
                         batch-aware cost model amortizes over)
      dispatcher       — runtime dispatcher spec ("inline" |
                         "threads[:N]" | "sharded[:N]"), a Dispatcher
                         instance, or None to read STRETTO_DISPATCHER

    Measured feedback (the measure -> plan loop)
      feedback         — seeds the session's MeasuredBatchStore: a store
                         instance, a directory of stage_stats*.json
                         trajectory snapshots to aggregate, or None for a
                         fresh empty store. Once the store holds measured
                         telemetry (loaded, via Session.record_measured,
                         or by a replan-on-drift), Session.plan() prices
                         operators at measured flush widths instead of
                         the static coalesce default.
    """
    cache_dir: Optional[str] = None
    models: Tuple[str, ...] = ("sm", "lg")
    profile_ratios: Optional[Tuple[float, ...]] = None
    prefill_batch: int = 16
    memory_budget_bytes: float = 2e9
    max_batch: int = 128
    model_seed: int = 1

    sm_ratios: Tuple[float, ...] = (0.8, 0.5, 0.0)
    lg_ratios: Tuple[float, ...] = (0.8, 0.5, 0.3)
    include_cheap: bool = True

    planner: Optional[PlannerConfig] = None
    sample_frac: float = 0.15
    seed: int = 0
    reorder: bool = True

    partition_size: Optional[int] = None
    coalesce: Optional[int] = None
    dispatcher: Optional[Any] = None

    feedback: Optional[Any] = None

    def ladder(self) -> Tuple[float, ...]:
        """The compression ratios profiles are built at (gold 0.0 always
        included — the reference backend needs it)."""
        if self.profile_ratios is not None:
            return tuple(sorted({0.0, *self.profile_ratios}))
        return tuple(sorted({0.0, *self.sm_ratios, *self.lg_ratios}))


class Session:
    """Context-managed front door to the engine.

    Three construction modes:

      Session()                      — owns everything: fresh cache store,
                                       planted models, profiles built
                                       lazily per corpus on first use
      Session(engine=eng)            — adopts an existing ServingEngine
                                       (models/profiles are the caller's;
                                       call .prepare(items) if needed)
      Session(backend=b)             — wraps any runtime Backend (e.g. an
                                       OracleBackend over a registry);
                                       no engine, no profile building —
                                       gold references come from the
                                       backend's own gold operators
    """

    def __init__(self, config: Optional[SessionConfig] = None, *,
                 engine=None, backend=None, reference=None, **overrides):
        if config is None:
            config = SessionConfig(**overrides)
        elif overrides:
            config = replace(config, **overrides)
        self.config = config
        self._closed = False
        self._owned_cache_dir: Optional[str] = None
        self._prepared: set = set()
        self._gold_cache: Dict[Any, RuntimeResult] = {}
        self._plan_cache: Dict[Any, PhysicalPlan] = {}
        # stable per-object corpus tokens for items without an item_id:
        # CPython reuses id() after GC, so raw ids must never key a memo
        # (two distinct corpora could silently share plan/gold entries).
        # Weak-referenceable objects get a counter token that dies with
        # them; the rest are pinned for the session's lifetime so their
        # ids cannot be recycled (growth bounded by the distinct keyless
        # corpora the session sees — over-invalidation is safe, collision
        # is not).
        self._obj_tokens: "weakref.WeakKeyDictionary[Any, int]" = \
            weakref.WeakKeyDictionary()
        self._pinned_tokens: Dict[int, int] = {}
        self._id_pins: List[Any] = []
        self._next_token = 0
        # measured execution feedback driving the measure -> plan loop
        fb = config.feedback
        if isinstance(fb, MeasuredBatchStore):
            self.measured = fb
        elif isinstance(fb, str):
            self.measured = MeasuredBatchStore.from_dir(fb)
        else:
            self.measured = MeasuredBatchStore()
        self.n_replans = 0

        self._owns_engine = engine is None and backend is None
        if backend is not None and engine is None:
            self.engine = None
        else:
            self.engine = engine if engine is not None \
                else self._build_engine()
        self.backend: Backend = as_backend(backend) \
            if backend is not None else self.backend_for()
        if reference is not None:
            self.reference = as_backend(reference)
        elif self.engine is not None:
            from repro.runtime.backend import ReferenceBackend
            self.reference = ReferenceBackend(self.engine)
        else:
            # no engine: the backend's own gold operators (candidates
            # list, gold last) are the reference
            self.reference = self.backend

    # ---------------- lifecycle ----------------

    def _build_engine(self):
        from repro.cache.store import CacheStore
        from repro.data.synthetic import make_planted_params, planted_config
        from repro.serving.engine import ServingEngine
        cfg = self.config
        cache_dir = cfg.cache_dir
        if cache_dir is None:
            cache_dir = tempfile.mkdtemp(prefix="stretto_session_")
            self._owned_cache_dir = cache_dir
        engine = ServingEngine(CacheStore(cache_dir),
                               memory_budget_bytes=cfg.memory_budget_bytes,
                               max_batch=cfg.max_batch)
        for name in cfg.models:
            mcfg = planted_config(name)
            engine.register_model(
                name, mcfg, make_planted_params(mcfg, seed=cfg.model_seed))
        return engine

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        """Release session-owned resources (idempotent). Only cache
        directories the session created itself are removed."""
        if self._closed:
            return
        self._closed = True
        if self._owned_cache_dir is not None:
            shutil.rmtree(self._owned_cache_dir, ignore_errors=True)

    # ---------------- offline phase ----------------

    def _object_token(self, it: Any) -> int:
        """A session-stable token for an item without an item_id. Unlike
        raw id(), tokens are never recycled: weak-referenceable items get
        a fresh counter entry that disappears with the object (a new
        object can never inherit it), everything else is pinned so its id
        stays unique for the session's lifetime."""
        try:
            tok = self._obj_tokens.get(it)
            if tok is None:
                tok = self._next_token
                self._next_token += 1
                self._obj_tokens[it] = tok
            return tok
        except TypeError:       # unhashable / no weakref support: pin it
            key = id(it)
            tok = self._pinned_tokens.get(key)
            if tok is None:
                self._id_pins.append(it)
                tok = self._next_token
                self._next_token += 1
                self._pinned_tokens[key] = tok
            return tok

    def _corpus_key(self, items: Sequence[Any]) -> Tuple:
        """Cheap corpus fingerprint for profile/plan/gold memoization:
        length plus (item_id, lead token) at a spread of sample
        positions. Items without an `item_id` use a session-held stable
        token (see _object_token) — never a raw id(), which CPython
        recycles after GC; distinct same-length corpora must never share
        a key (over-invalidation is safe, collision is not)."""
        n = len(items)
        step = max(n // 16, 1)
        probe = []
        for it in items[::step]:
            toks = getattr(it, "tokens", None)
            lead = toks[0] if toks is not None and len(toks) else None
            item_id = getattr(it, "item_id", None)
            probe.append((item_id if item_id is not None
                          else ("obj", self._object_token(it)), lead))
        return (n, tuple(probe))

    def prepare(self, items: Sequence[Any],
                ratios: Optional[Sequence[float]] = None) -> None:
        """Build KV-cache profiles for this corpus (offline phase). Safe
        to call repeatedly — each (corpus, ladder) is built once."""
        if self.engine is None:
            return                      # backend-only session: nothing to do
        ladder = tuple(sorted({0.0, *(ratios or self.config.ladder())}))
        key = (self._corpus_key(items), ladder)
        if key in self._prepared:
            return
        for name in self.config.models:
            self.engine.build_profiles(
                name, items, ratios=list(ladder),
                prefill_batch=self.config.prefill_batch)
        self._prepared.add(key)

    def _ensure_prepared(self, items: Sequence[Any]) -> None:
        # adopted engines manage their own profiles; session-owned
        # engines build lazily on first use of a corpus
        if self._owns_engine:
            self.prepare(items)

    # ---------------- backends ----------------

    def backend_for(self, *, sm_ratios: Optional[Tuple[float, ...]] = None,
                    lg_ratios: Optional[Tuple[float, ...]] = None,
                    include_cheap: Optional[bool] = None) -> Backend:
        """A KVCacheBackend over the session engine with an alternative
        candidate ladder (defaults: the session config's ladder)."""
        if self.engine is None:
            raise RuntimeError("session has no engine: it wraps an "
                               "externally supplied backend")
        from repro.runtime.backend import KVCacheBackend
        cfg = self.config
        return KVCacheBackend(
            self.engine,
            sm_ratios=sm_ratios if sm_ratios is not None else cfg.sm_ratios,
            lg_ratios=lg_ratios if lg_ratios is not None else cfg.lg_ratios,
            include_cheap=cfg.include_cheap if include_cheap is None
            else include_cheap)

    # ---------------- query building ----------------

    def frame(self, items: Sequence[Any], query: Optional[Query] = None):
        """A lazy SemFrame over `items` (a sequence of corpus items, or
        anything exposing `.items` such as a Dataset). Pass `query` to
        seed the frame from an existing logical Query."""
        from repro.api.frame import SemFrame
        items = getattr(items, "items", items)
        if query is not None:
            return SemFrame(self, items, tuple(query.nodes),
                            query.target_recall, query.target_precision)
        return SemFrame(self, items)

    # ---------------- internal layer (plan / execute / gold) ----------

    def _exec_kwargs(self, partition_size=_UNSET, coalesce=_UNSET,
                     dispatcher=_UNSET) -> Dict[str, Any]:
        cfg = self.config
        return {
            "partition_size": cfg.partition_size
            if partition_size is _UNSET else partition_size,
            "coalesce": cfg.coalesce if coalesce is _UNSET else coalesce,
            "dispatcher": cfg.dispatcher
            if dispatcher is _UNSET else dispatcher,
        }

    def plan(self, query: Query, items: Sequence[Any]) -> PhysicalPlan:
        """Plan `query` over `items` with the session's planner settings
        (memoized per (corpus, query, measured-feedback version) —
        explain + execute share a plan; recording new measured telemetry
        bumps the store version, so the next plan() re-plans against the
        updated flush widths). When the session's MeasuredBatchStore
        holds telemetry, BatchHint is seeded from measured flush widths
        instead of the static coalesce default."""
        self._ensure_prepared(items)
        key = (self._corpus_key(items), tuple(query.nodes),
               query.target_recall, query.target_precision,
               self.measured.version if len(self.measured) else 0)
        plan = self._plan_cache.get(key)
        if plan is None:
            cfg = self.config
            plan = plan_query(
                query, items, self.backend, cfg.planner,
                sample_frac=cfg.sample_frac, seed=cfg.seed,
                reorder=cfg.reorder,
                coalesce=cfg.coalesce if cfg.coalesce is not None
                else DEFAULT_COALESCE,
                measured=self.measured if len(self.measured) else None)
            self._plan_cache[key] = plan
        return plan

    def record_measured(self, result: RuntimeResult) -> None:
        """Feed a result's measured StageStats into the session's
        MeasuredBatchStore, so subsequent plan() calls price operators at
        the flush widths execution actually delivered."""
        self.measured.record_result(result)

    def run(self, plan: PhysicalPlan, query: Query, items: Sequence[Any],
            backend: Optional[Backend] = None, *, partition_size=_UNSET,
            coalesce=_UNSET, dispatcher=_UNSET,
            replan_on_drift: Optional[float] = None) -> RuntimeResult:
        """Execute a prebuilt plan through the streaming runtime with the
        session's execution defaults.

        replan_on_drift — when set (a factor > 1), compare each executed
        stage's measured mean flush batch against the plan's expected
        batch after the run; if any stage diverges by more than the
        factor (either direction), record the measured telemetry into the
        session's MeasuredBatchStore, re-plan the query against the
        measured widths, and re-execute once with the corrected plan
        (returning the second result). The paper's cost model is only as
        good as its batch expectations — this is the cheap online
        correction for when reality disagrees. Only valid when the run
        executes the session's own backend: re-planning profiles against
        `self.backend`, so a caller-supplied backend would be re-planned
        on the wrong operator ladder and its stats would pollute the
        session's measured store.
        """
        self._ensure_prepared(items)
        if replan_on_drift is not None and backend is not None \
                and backend is not self.backend:
            raise ValueError(
                "replan_on_drift requires the session backend: re-planning "
                "profiles against session.backend, which is not the "
                "backend this run would execute on")
        kwargs = self._exec_kwargs(partition_size, coalesce, dispatcher)
        result = run_plan(plan, query, items, backend or self.backend,
                          **kwargs)
        if replan_on_drift is not None:
            drift = batch_drift(plan, result.stage_stats)
            if drift > float(replan_on_drift):
                self.record_measured(result)
                self.n_replans += 1
                new_plan = self.plan(query, items)
                result = run_plan(new_plan, query, items,
                                  backend or self.backend, **kwargs)
        return result

    def iter_run(self, plan: PhysicalPlan, query: Query,
                 items: Sequence[Any], backend: Optional[Backend] = None, *,
                 partition_size=_UNSET, coalesce=_UNSET, dispatcher=_UNSET):
        """Generator form of `run` (yields PartitionResult per settled
        partition; StopIteration.value is the RuntimeResult)."""
        self._ensure_prepared(items)
        return iter_plan(plan, query, items, backend or self.backend,
                         **self._exec_kwargs(partition_size, coalesce,
                                             dispatcher))

    def gold(self, query: Query, items: Sequence[Any]) -> RuntimeResult:
        """The gold reference execution for `query` over `items` (every
        semantic op resolved by the reference backend's gold operator),
        memoized per (corpus, query nodes)."""
        self._ensure_prepared(items)
        key = (self._corpus_key(items), tuple(query.nodes))
        got = self._gold_cache.get(key)
        if got is None:
            gold_plan = gold_plan_for(query, self.reference)
            got = run_plan(gold_plan, query, items, self.reference,
                           **self._exec_kwargs())
            self._gold_cache[key] = got
        return got
