"""SemFrame: a lazy, immutable semantic-query builder.

Every chain method returns a *new* frame — frames are never mutated, so a
partially built chain can be reused and branched freely::

    base = sess.frame(items).sem_filter("is about sports", task_id=1)
    strict = base.with_guarantees(recall=0.95, precision=0.95)
    loose = base.with_guarantees(recall=0.6, precision=0.6)

Nothing executes until a terminal verb:

    .explain()   — plan only: a structured ExplainReport (logical plan,
                   physical cascade stages with thresholds and batch-aware
                   costs, bounds, feasibility), rendered as a table
    .execute()   — plan + run through the streaming runtime; returns a
                   QueryResult with lazy `.metrics()` gold comparison
    .stream()    — plan + run incrementally; a ResultStream yielding
                   PartitionResult per corpus partition as soon as its
                   decisions are final (the whole-corpus QueryResult is
                   available afterwards as `.result`)

A frame compiles to the stable internal layer verbatim: `.to_query()` is
the exact `core.logical.Query` a hand-built pipeline would construct, and
planning/execution run through `plan_query` / `run_plan` unchanged — the
API-parity tests pin bit-identical decisions between the two paths.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

from repro.core.logical import Query, RelFilter, SemFilter, SemMap
from repro.core.physical import PhysicalPlan

from repro.api.session import _UNSET


class SemFrame:
    """Lazy query over one corpus, bound to a Session."""

    __slots__ = ("_session", "_items", "_nodes", "_recall", "_precision")

    def __init__(self, session, items: Sequence[Any],
                 nodes: Tuple[Any, ...] = (),
                 recall: Optional[float] = None,
                 precision: Optional[float] = None):
        self._session = session
        self._items = items
        self._nodes = tuple(nodes)
        self._recall = recall
        self._precision = precision

    # ---------------- chainable builders (each returns a new frame) ----

    def _with(self, node) -> "SemFrame":
        return SemFrame(self._session, self._items, self._nodes + (node,),
                        self._recall, self._precision)

    def sem_filter(self, text: str, task_id: int,
                   modality: str = "text") -> "SemFrame":
        """Keep items satisfying an LLM-powered natural-language
        predicate (`task_id` names the dataset task it evaluates)."""
        return self._with(SemFilter(text, task_id, modality))

    def sem_map(self, text: str, task_id: int, *,
                out_column: str = "extracted",
                modality: str = "text") -> "SemFrame":
        """Extract a new column with an LLM-powered map."""
        return self._with(SemMap(text, task_id, out_column, modality))

    def filter(self, column: str, op: str, value: Any) -> "SemFrame":
        """Classical relational predicate over structured columns (cheap;
        the optimizer pulls these ahead of every semantic operator)."""
        return self._with(RelFilter(column, op, value))

    def with_guarantees(self, recall: Optional[float] = None,
                        precision: Optional[float] = None) -> "SemFrame":
        """Declare end-to-end quality targets the plan must satisfy
        (either side defaults to the previously declared value)."""
        return SemFrame(
            self._session, self._items, self._nodes,
            self._recall if recall is None else float(recall),
            self._precision if precision is None else float(precision))

    # ---------------- compilation ----------------

    @property
    def nodes(self) -> Tuple[Any, ...]:
        return self._nodes

    @property
    def items(self) -> Sequence[Any]:
        return self._items

    def to_query(self) -> Query:
        """Compile to the internal logical Query (the exact object a
        hand-built pipeline would pass to plan_query)."""
        kwargs = {}
        if self._recall is not None:
            kwargs["target_recall"] = self._recall
        if self._precision is not None:
            kwargs["target_precision"] = self._precision
        return Query(list(self._nodes), **kwargs)

    def plan(self) -> PhysicalPlan:
        """The physical cascade plan (memoized by the session, so
        explain/execute/stream on equal frames plan once)."""
        self._check_nonempty()
        return self._session.plan(self.to_query(), self._items)

    # ---------------- terminal verbs ----------------

    def explain(self):
        """Plan without executing: a structured, renderable report of the
        logical plan, cascade stages, bounds and costs."""
        from repro.api.explain import ExplainReport
        return ExplainReport.from_plan(
            self._session, self.to_query(), self._items, self.plan())

    def execute(self, *, partition_size=_UNSET, coalesce=_UNSET,
                dispatcher=_UNSET, replan_on_drift=None):
        """Plan + execute over the full corpus; returns a QueryResult.
        `replan_on_drift` forwards to Session.run: re-plan + re-execute
        once if measured flush batches diverge from planned by more than
        the given factor."""
        from repro.api.result import QueryResult
        query = self.to_query()
        raw = self._session.run(self.plan(), query, self._items,
                                partition_size=partition_size,
                                coalesce=coalesce, dispatcher=dispatcher,
                                replan_on_drift=replan_on_drift)
        return QueryResult(self._session, query, self._items, raw)

    def stream(self, *, partition_size=_UNSET, coalesce=_UNSET,
               dispatcher=_UNSET):
        """Plan + execute incrementally: a ResultStream yielding one
        PartitionResult per corpus partition as soon as every tuple in it
        has cleared the cascade — million-tuple corpora can be consumed
        while later partitions are still executing."""
        from repro.api.result import ResultStream
        query = self.to_query()
        gen = self._session.iter_run(self.plan(), query, self._items,
                                     partition_size=partition_size,
                                     coalesce=coalesce,
                                     dispatcher=dispatcher)
        return ResultStream(self._session, query, self._items, gen)

    # ---------------- misc ----------------

    def _check_nonempty(self) -> None:
        if not self._nodes:
            raise ValueError("empty SemFrame: add sem_filter / sem_map / "
                             "filter operators before a terminal verb")

    def __repr__(self) -> str:
        q = self.to_query()
        parts = [f"{type(n).__name__}({getattr(n, 'text', getattr(n, 'column', ''))!r})"
                 for n in self._nodes]
        return (f"SemFrame({len(self._items)} items, "
                f"[{', '.join(parts)}], R>={q.target_recall}, "
                f"P>={q.target_precision})")
