"""SemFrame: a lazy, immutable semantic-query builder.

Every chain method returns a *new* frame — frames are never mutated, so a
partially built chain can be reused and branched freely::

    base = sess.frame(items).sem_filter("is about sports", task_id=1)
    strict = base.with_guarantees(recall=0.95, precision=0.95)
    loose = base.with_guarantees(recall=0.6, precision=0.6)

Nothing executes until a terminal verb:

    .explain()   — plan only: a structured ExplainReport (logical plan,
                   physical cascade stages with thresholds and batch-aware
                   costs, bounds, feasibility), rendered as a table
    .execute()   — plan + run through the streaming runtime; returns a
                   QueryResult with lazy `.metrics()` gold comparison
    .stream()    — plan + run incrementally; a ResultStream yielding
                   PartitionResult per corpus partition as soon as its
                   decisions are final (the whole-corpus QueryResult is
                   available afterwards as `.result`)

A frame compiles to the stable internal layer verbatim: `.to_query()` is
the exact `core.logical.Query` a hand-built pipeline would construct, and
planning/execution run through `plan_query` / `run_plan` unchanged — the
API-parity tests pin bit-identical decisions between the two paths.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

from repro.core.logical import (JoinNode, PipelineLeaf, Query, RelFilter,
                                SemAgg, SemFilter, SemJoin, SemMap, SemTopK)
from repro.core.physical import PhysicalPlan

from repro.api.session import _UNSET


class SemFrame:
    """Lazy query over one corpus, bound to a Session."""

    __slots__ = ("_session", "_items", "_nodes", "_recall", "_precision")

    def __init__(self, session, items: Sequence[Any],
                 nodes: Tuple[Any, ...] = (),
                 recall: Optional[float] = None,
                 precision: Optional[float] = None):
        self._session = session
        self._items = items
        self._nodes = tuple(nodes)
        self._recall = recall
        self._precision = precision

    # ---------------- chainable builders (each returns a new frame) ----

    def _with(self, node) -> "SemFrame":
        return SemFrame(self._session, self._items, self._nodes + (node,),
                        self._recall, self._precision)

    def sem_filter(self, text: str, task_id: int,
                   modality: str = "text") -> "SemFrame":
        """Keep items satisfying an LLM-powered natural-language
        predicate (`task_id` names the dataset task it evaluates)."""
        return self._with(SemFilter(text, task_id, modality))

    def sem_map(self, text: str, task_id: int, *,
                out_column: str = "extracted",
                modality: str = "text") -> "SemFrame":
        """Extract a new column with an LLM-powered map."""
        return self._with(SemMap(text, task_id, out_column, modality))

    def sem_topk(self, text: str, task_id: int, k: int, *,
                 modality: str = "text") -> "SemFrame":
        """Keep the k best items under an LLM-scored ranking criterion.

        Scored like a sem_filter, but admission is a global rank cut:
        the cascade's cheap stages may only *reject* early (early
        termination), and the final result is the k top gold-scored
        survivors — so the accept boundary is schedule-invariant."""
        return self._with(SemTopK(text, task_id, modality=modality, k=k))

    def sem_agg(self, text: str, task_id: int, *,
                group_by: Optional[str] = None, how: str = "mode",
                out_column: str = "aggregated",
                modality: str = "text") -> "SemFrame":
        """Group-wise aggregate of an LLM-extracted value: executes as
        the underlying extraction (one committed value per survivor),
        aggregated per `group_by` group by `QueryResult.aggregate()`.
        The planner tightens per-item budgets so the *group-level*
        guarantee holds (see core.logical.SemAgg)."""
        return self._with(SemAgg(text, task_id, out_column=out_column,
                                 modality=modality, group_by=group_by,
                                 how=how))

    def filter(self, column: str, op: str, value: Any) -> "SemFrame":
        """Classical relational predicate over structured columns (cheap;
        the optimizer pushes these ahead of every semantic operator when
        legal — a predicate over a sem_map's output column, or one
        declared after a sem_topk/sem_agg barrier, stays pinned and runs
        as a post-filter)."""
        return self._with(RelFilter(column, op, value))

    def sem_join(self, other: Any, text: str, task_id: int, *,
                 on: Optional[str] = None,
                 modality: str = "text") -> "JoinFrame":
        """Join this frame against a second corpus on an LLM-evaluated
        pair predicate (`task_id` names the extraction task whose
        agreement defines a match). `other` is another SemFrame (its
        chained operators become the right side's pipeline) or a bare
        item sequence / Dataset. `on` optionally names a structured row
        column both corpora carry: candidate pairs are then blocked on
        equality of that column before any LLM stage prices them.

        Returns a JoinFrame — the two-corpus builder whose terminal
        verbs plan through `Session.plan_tree` (one grouped relaxation
        allocating the recall/precision budget across the left / right /
        pair pipelines) and execute through the tree runtime."""
        if isinstance(other, SemFrame):
            right_items, right_nodes = other._items, other._nodes
        else:
            right_items = getattr(other, "items", other)
            right_nodes = ()
        return JoinFrame(self._session, self._items, right_items,
                         self._nodes, tuple(right_nodes),
                         SemJoin(text, task_id, on, modality), (),
                         self._recall, self._precision)

    def with_guarantees(self, recall: Optional[float] = None,
                        precision: Optional[float] = None) -> "SemFrame":
        """Declare end-to-end quality targets the plan must satisfy
        (either side defaults to the previously declared value)."""
        return SemFrame(
            self._session, self._items, self._nodes,
            self._recall if recall is None else float(recall),
            self._precision if precision is None else float(precision))

    # ---------------- compilation ----------------

    @property
    def nodes(self) -> Tuple[Any, ...]:
        return self._nodes

    @property
    def items(self) -> Sequence[Any]:
        return self._items

    def to_query(self) -> Query:
        """Compile to the internal logical Query (the exact object a
        hand-built pipeline would pass to plan_query)."""
        kwargs = {}
        if self._recall is not None:
            kwargs["target_recall"] = self._recall
        if self._precision is not None:
            kwargs["target_precision"] = self._precision
        return Query(list(self._nodes), **kwargs)

    def plan(self) -> PhysicalPlan:
        """The physical cascade plan (memoized by the session, so
        explain/execute/stream on equal frames plan once)."""
        self._check_nonempty()
        return self._session.plan(self.to_query(), self._items)

    # ---------------- terminal verbs ----------------

    def explain(self):
        """Plan without executing: a structured, renderable report of the
        logical plan, cascade stages, bounds and costs."""
        from repro.api.explain import ExplainReport
        return ExplainReport.from_plan(
            self._session, self.to_query(), self._items, self.plan())

    def execute(self, *, partition_size=_UNSET, coalesce=_UNSET,
                dispatcher=_UNSET, replan_on_drift=None):
        """Plan + execute over the full corpus; returns a QueryResult.
        `replan_on_drift` forwards to Session.run: re-plan + re-execute
        once if measured flush batches diverge from planned by more than
        the given factor."""
        from repro.api.result import QueryResult
        query = self.to_query()
        raw = self._session.run(self.plan(), query, self._items,
                                partition_size=partition_size,
                                coalesce=coalesce, dispatcher=dispatcher,
                                replan_on_drift=replan_on_drift)
        return QueryResult(self._session, query, self._items, raw)

    def stream(self, *, partition_size=_UNSET, coalesce=_UNSET,
               dispatcher=_UNSET):
        """Plan + execute incrementally: a ResultStream yielding one
        PartitionResult per corpus partition as soon as every tuple in it
        has cleared the cascade — million-tuple corpora can be consumed
        while later partitions are still executing."""
        from repro.api.result import ResultStream
        query = self.to_query()
        gen = self._session.iter_run(self.plan(), query, self._items,
                                     partition_size=partition_size,
                                     coalesce=coalesce,
                                     dispatcher=dispatcher)
        return ResultStream(self._session, query, self._items, gen)

    # ---------------- misc ----------------

    def _check_nonempty(self) -> None:
        if not self._nodes:
            raise ValueError("empty SemFrame: add sem_filter / sem_map / "
                             "filter operators before a terminal verb")

    def __repr__(self) -> str:
        q = self.to_query()
        parts = [f"{type(n).__name__}({getattr(n, 'text', getattr(n, 'column', ''))!r})"
                 for n in self._nodes]
        return (f"SemFrame({len(self._items)} items, "
                f"[{', '.join(parts)}], R>={q.target_recall}, "
                f"P>={q.target_precision})")


class JoinFrame:
    """Lazy two-corpus semantic join, bound to a Session.

    Built by `SemFrame.sem_join`; immutable like SemFrame. Compiles to a
    logical `JoinNode` tree (each side a PipelineLeaf) that
    `Session.plan_tree` optimizes *jointly*: one grouped gradient
    relaxation places thresholds for the left side, right side, and
    pairing cascade at once, splitting the query-level recall/precision
    budget across all three pipelines (visible in `.explain()`).

    Terminal verbs:
      .explain()  — the tree-shaped TreeExplainReport (per-role cascade
                    tables around the joint bounds + budget split)
      .execute()  — run left side, right side, then the pair cascade
                    over blocked survivor pairs; returns a JoinResult
                    with lazy `.metrics()` against the gold join
    """

    __slots__ = ("_session", "_left_items", "_right_items", "_left_nodes",
                 "_right_nodes", "_join", "_pair_nodes", "_recall",
                 "_precision")

    def __init__(self, session, left_items: Sequence[Any],
                 right_items: Sequence[Any], left_nodes: Tuple[Any, ...],
                 right_nodes: Tuple[Any, ...], join: SemJoin,
                 pair_nodes: Tuple[Any, ...] = (),
                 recall: Optional[float] = None,
                 precision: Optional[float] = None):
        self._session = session
        self._left_items = left_items
        self._right_items = right_items
        self._left_nodes = tuple(left_nodes)
        self._right_nodes = tuple(right_nodes)
        self._join = join
        self._pair_nodes = tuple(pair_nodes)
        self._recall = recall
        self._precision = precision

    # ---------------- chainable builders ----------------

    def filter(self, column: str, op: str, value: Any) -> "JoinFrame":
        """Relational predicate over the joined pair rows (``left_`` /
        ``right_`` prefixed columns, plus bare names for shared columns
        whose values agree on both sides). Runs in the pair cascade."""
        return JoinFrame(self._session, self._left_items,
                         self._right_items, self._left_nodes,
                         self._right_nodes, self._join,
                         self._pair_nodes + (RelFilter(column, op, value),),
                         self._recall, self._precision)

    def with_guarantees(self, recall: Optional[float] = None,
                        precision: Optional[float] = None) -> "JoinFrame":
        """Declare end-to-end quality targets for the whole join — the
        planner allocates them across the tree's pipelines."""
        return JoinFrame(
            self._session, self._left_items, self._right_items,
            self._left_nodes, self._right_nodes, self._join,
            self._pair_nodes,
            self._recall if recall is None else float(recall),
            self._precision if precision is None else float(precision))

    # ---------------- compilation ----------------

    def to_tree(self) -> JoinNode:
        """Compile to the internal logical join tree."""
        return JoinNode(PipelineLeaf(self._left_nodes),
                        PipelineLeaf(self._right_nodes),
                        self._join, self._pair_nodes)

    def plan(self):
        """The jointly optimized TreePlan (memoized by the session)."""
        return self._session.plan_tree(
            self.to_tree(), self._left_items, self._right_items,
            target_recall=0.9 if self._recall is None else self._recall,
            target_precision=0.9 if self._precision is None
            else self._precision)

    # ---------------- terminal verbs ----------------

    def explain(self):
        """Plan without executing: the tree-shaped report — joint
        bounds, the per-pipeline budget split, and each role's cascade
        table."""
        from repro.api.explain import TreeExplainReport
        return TreeExplainReport.from_plan(
            self._session, self.plan(), len(self._left_items),
            len(self._right_items))

    def execute(self, *, partition_size=_UNSET, coalesce=_UNSET,
                dispatcher=_UNSET):
        """Plan + execute the tree; returns a JoinResult."""
        from repro.api.result import JoinResult
        raw = self._session.run_tree(
            self.plan(), self._left_items, self._right_items,
            partition_size=partition_size, coalesce=coalesce,
            dispatcher=dispatcher)
        return JoinResult(self._session, self._left_items,
                          self._right_items, raw)

    def __repr__(self) -> str:
        return (f"JoinFrame({len(self._left_items)} x "
                f"{len(self._right_items)} items, "
                f"join={self._join.text!r}, on={self._join.on!r}, "
                f"R>={0.9 if self._recall is None else self._recall}, "
                f"P>={0.9 if self._precision is None else self._precision})")
